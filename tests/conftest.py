"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests and benches see
the host's single CPU device; only launch/dryrun.py forces 512 devices."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import pytest

from repro.configs.registry import ARCH_IDS, get_smoke_config


def smoke_batch(cfg, B=2, S=16, seed=0, with_labels=True):
    """Batch matching a (possibly multimodal) smoke config."""
    key = jax.random.PRNGKey(seed)
    batch = {}
    if cfg.embeds_input:
        batch["embeds"] = jax.random.normal(key, (B, S, cfg.d_model),
                                            jnp.dtype(cfg.dtype))
    else:
        batch["tokens"] = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
        if cfg.vision_tokens:
            batch["vision_embeds"] = jax.random.normal(
                key, (B, cfg.vision_tokens, cfg.d_model),
                jnp.dtype(cfg.dtype))
    if with_labels:
        batch["labels"] = jax.random.randint(jax.random.PRNGKey(seed + 1),
                                             (B, S), 0, cfg.vocab_size)
    return batch


def moe_no_drop(cfg):
    """Raise MoE capacity so routing never drops (for exact-consistency
    tests; dropping is data-dependent and differs between T=B*S and T=B)."""
    if cfg.moe is None:
        return cfg
    import dataclasses
    return cfg.replace(moe=dataclasses.replace(
        cfg.moe, capacity_factor=float(cfg.moe.num_experts) / cfg.moe.top_k))


@pytest.fixture(scope="session")
def arch_ids():
    return ARCH_IDS


@pytest.fixture(scope="session", params=ARCH_IDS)
def smoke_cfg(request):
    return get_smoke_config(request.param).replace(dtype="float32")
