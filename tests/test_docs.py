"""Documentation contract checks.

Two promises this suite pins down:

  1. every public symbol of the serving surface (``repro.serving``
     exports, plus the protocol codec helpers) carries a docstring —
     the API is self-documenting, with units spelled out;
  2. the ``docs/`` pages and the README never drift from the code:
     every file path they reference exists in the repo, every
     markdown link resolves, and every CLI flag they quote for a repo
     script actually appears in that script.

Plus the naming audit for the energy subsystem: the batching layer's
power-of-two bucket vocabulary and the energy layer's power/joule keys
must never collide in plan JSON or stats records (all energy keys are
unit-suffixed).
"""
from __future__ import annotations

import inspect
import json
import os
import re

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DOC_PAGES = ["docs/architecture.md", "docs/wire-protocol.md",
             "docs/deployment-plan.md", "docs/benchmarks.md",
             "docs/fleet-sim.md", "docs/static-analysis.md",
             "docs/quantized-edge.md"]
#: generated artifacts (gitignored): referenced by the docs but not
#: present in a fresh checkout
GENERATED_PREFIXES = ("experiments/",)


# ---------------------------------------------------------------------------
# docstring presence on the public serving surface
# ---------------------------------------------------------------------------
def _public_members(mod):
    names = getattr(mod, "__all__", None)
    for name in names or vars(mod):
        if name.startswith("_"):
            continue
        obj = getattr(mod, name)
        if not (inspect.isclass(obj) or inspect.isfunction(obj)):
            continue
        # without an __all__, scan only symbols the module defines (not
        # its imports — those are audited where they live)
        if names is None and getattr(obj, "__module__", "") != mod.__name__:
            continue
        yield name, obj


def test_serving_surface_has_docstrings():
    from repro import serving
    from repro.core.collab import protocol
    from repro.serving import plan, session

    missing = []
    for mod in (serving, plan, session, protocol):
        assert (mod.__doc__ or "").strip(), f"{mod.__name__} has no docstring"
        for name, obj in _public_members(mod):
            if not (inspect.getdoc(obj) or "").strip():
                missing.append(f"{mod.__name__}.{name}")
            if inspect.isclass(obj) and obj.__module__.startswith("repro"):
                for mname, meth in vars(obj).items():
                    if mname.startswith("_") or not callable(meth):
                        continue
                    if not (inspect.getdoc(meth) or "").strip():
                        missing.append(f"{mod.__name__}.{name}.{mname}")
    assert not missing, f"public serving symbols without docstrings: {missing}"


def test_energy_model_documents_units():
    """The energy surface spells out its units: watts in the profile
    docs, joules on the per-request quantities."""
    from repro.core.partition import energy_model as em
    assert "joule" in em.__doc__.lower()
    assert "watt" in inspect.getdoc(em.EnergyProfile).lower()
    assert "joule" in inspect.getdoc(em.EnergyProfile.request_energy).lower()
    assert "joule" in inspect.getdoc(em.pareto_front).lower()


# ---------------------------------------------------------------------------
# docs/ pages: existence, links, file references, CLI flags
# ---------------------------------------------------------------------------
def _read(rel):
    with open(os.path.join(REPO, rel)) as f:
        return f.read()


def test_doc_pages_exist_and_readme_links_them():
    readme = _read("README.md")
    for page in DOC_PAGES:
        assert os.path.exists(os.path.join(REPO, page)), f"missing {page}"
        assert page in readme, f"README does not link {page}"


_PATH_RE = re.compile(r"[\w.][\w./-]*/[\w.-]+\.(?:py|md|json|yml|ini|txt)")
_CMD_RE = re.compile(r"python\s+(?:-m\s+([\w.]+)|([\w./-]+\.py))([^\n|]*)")
_FLAG_RE = re.compile(r"--[\w-]+")
_LINK_RE = re.compile(r"\]\(([^)#]+)(?:#[^)]*)?\)")


def _referenced_paths(text):
    for m in _PATH_RE.finditer(text):
        yield m.group(0)


@pytest.mark.parametrize("page", DOC_PAGES + ["README.md"])
def test_doc_file_references_resolve(page):
    """Every repo-relative file path a page mentions must exist (paths
    under generated output dirs are exempt — they are gitignored
    artifacts the docs describe how to produce)."""
    text = _read(page)
    missing = []
    for ref in _referenced_paths(text):
        if ref.startswith(GENERATED_PREFIXES):
            continue
        if not os.path.exists(os.path.join(REPO, ref)):
            missing.append(ref)
    assert not missing, f"{page} references missing files: {missing}"


@pytest.mark.parametrize("page", DOC_PAGES)
def test_doc_markdown_links_resolve(page):
    """Relative markdown links inside docs/ resolve to real files."""
    text = _read(page)
    base = os.path.dirname(os.path.join(REPO, page))
    broken = []
    for m in _LINK_RE.finditer(text):
        target = m.group(1).strip()
        if "://" in target or target.startswith("mailto:"):
            continue
        if target.startswith(GENERATED_PREFIXES):
            continue
        cand = (os.path.join(REPO, target) if target.startswith(("src/",
                "docs/", "benchmarks/", "examples/", "tests/"))
                else os.path.join(base, target))
        if not os.path.exists(cand):
            broken.append(target)
    assert not broken, f"{page} has broken links: {broken}"


@pytest.mark.parametrize("page", DOC_PAGES + ["README.md"])
def test_doc_cli_commands_reference_real_flags(page):
    """``python -m pkg.mod --flag`` / ``python path.py --flag`` lines in
    the docs must name a repo script that actually defines each quoted
    flag (greps the script source for the flag literal)."""
    text = _read(page)
    problems = []
    for m in _CMD_RE.finditer(text):
        mod, script, rest = m.groups()
        rel = script if script else mod.replace(".", "/") + ".py"
        path = os.path.join(REPO, rel)
        if not script and not os.path.exists(path):
            # ``python -m pkg`` may name a package: try its __main__.py
            # (both at the repo root and under src/)
            for cand in (mod.replace(".", "/") + "/__main__.py",
                         "src/" + mod.replace(".", "/") + ".py",
                         "src/" + mod.replace(".", "/") + "/__main__.py"):
                if os.path.exists(os.path.join(REPO, cand)):
                    rel, path = cand, os.path.join(REPO, cand)
                    break
        if not os.path.exists(path):
            if script or mod.split(".")[0] in ("benchmarks", "examples",
                                               "repro"):
                problems.append(f"{m.group(0)!r}: {rel} does not exist")
            continue                    # stdlib/third-party -m: skip flags
        src = _read(rel)
        for flag in _FLAG_RE.findall(rest or ""):
            if flag not in src:
                problems.append(f"{rel} does not define {flag}")
    assert not problems, f"{page}: {problems}"


# ---------------------------------------------------------------------------
# power-naming audit: power-of-two buckets vs energy power/joule keys
# ---------------------------------------------------------------------------
def _flatten_keys(d, prefix=""):
    out = set()
    for k, v in d.items():
        out.add(k)
        if isinstance(v, dict):
            out |= _flatten_keys(v, prefix + k + ".")
    return out


def test_energy_keys_cannot_collide_with_batching_vocabulary():
    """The batching layer owns the power-of-two *bucket* vocabulary
    (``buckets``/``max_batch``/``padded_rows``); the energy layer's JSON
    keys are all unit-suffixed (``*_power_w``/``*_j``/``*_s_per_j``/
    weights) — the two vocabularies must stay disjoint so ``plan.json``
    sections and stats records can never shadow each other."""
    from repro.core.collab.batching import BatchingPolicy, LaneStats
    from repro.core.partition.energy_model import MCU_ENERGY, EnergyPolicy

    energy_keys = _flatten_keys(
        EnergyPolicy(profile=MCU_ENERGY, energy_weight_s_per_j=1.0,
                     battery_j=2.0).to_json())
    batching_keys = _flatten_keys(BatchingPolicy().to_json())
    lane_keys = _flatten_keys(LaneStats(lane=("l",)).to_json())
    overlap = energy_keys & (batching_keys | lane_keys)
    assert not overlap, (
        f"energy JSON keys collide with batching vocabulary: {overlap}")
    # every energy scalar is unit-suffixed or an explicit weight/name
    for k in energy_keys - {"profile", "radio", "name"}:
        assert k.endswith(("_w", "_j", "_s_per_j", "_weight")), (
            f"energy key {k!r} lacks a unit suffix")


def test_plan_json_sections_unique_and_unit_suffixed(tmp_path):
    """A plan carrying all three optional sections saves a plan.json
    whose section names are unique and whose energy keys are the
    audited unit-suffixed set."""
    import jax
    from repro import serving
    from repro.core.pruning.masks import cnn_masks_from_ratios
    from repro.models.cnn import (init_cnn_params, prunable_layers,
                                  tiny_cnn_config)
    cfg = tiny_cnn_config(num_classes=5, hw=32)
    params = init_cnn_params(jax.random.PRNGKey(0), cfg)
    masks = cnn_masks_from_ratios(params, cfg,
                                  {i: 0.5 for i in prunable_layers(cfg)})
    plan = serving.DeploymentPlan.from_args(
        params, cfg, 3, masks=masks, compact=True,
        adaptive=serving.AdaptivePolicy(candidates=(0, 3)),
        batching=serving.BatchingPolicy(max_batch=4),
        energy=serving.EnergyPolicy(profile=serving.MCU_ENERGY))
    path = plan.save(str(tmp_path / "deploy"))
    with open(os.path.join(path, "plan.json")) as f:
        doc = json.load(f)
    assert {"adaptive", "batching", "energy"} <= set(doc)
    assert set(doc["energy"]) == {"profile", "latency_weight",
                                  "energy_weight_s_per_j", "battery_j"}
    assert set(doc["batching"]) == {"max_batch", "max_wait_ms", "buckets"}
    reloaded = serving.DeploymentPlan.load(path)
    assert reloaded.digest == plan.digest
    assert reloaded.energy == plan.energy
