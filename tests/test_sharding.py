"""Sharding planner: specs are valid (divisible), cover the tree, and a
small shard_map'd train step runs on a host mesh."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from conftest import smoke_batch
from repro.configs.registry import ARCH_IDS, get_smoke_config
from repro.launch.mesh import make_host_mesh
from repro.launch.specs import SHAPES, input_specs, mode_of, supported
from repro.models import transformer as tr
from repro.sharding.specs import (batch_specs, cache_specs, mesh_axes,
                                  param_specs)


def _axis_sizes(mesh):
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def _check_divisibility(tree, specs, mesh):
    sizes = _axis_sizes(mesh)
    flat_t = jax.tree_util.tree_leaves(tree)
    flat_s = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_t) == len(flat_s)
    for leaf, spec in zip(flat_t, flat_s):
        shape = np.shape(leaf)
        for dim, ax in zip(shape, tuple(spec)):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            total = int(np.prod([sizes[a] for a in axes]))
            assert dim % total == 0, (shape, spec)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_specs_divisible_on_fake_mesh(arch):
    """Validate the FULL config's specs against a tiny (2, 4) mesh stand-in
    (divisibility logic is size-relative, so a small mesh exercises it)."""
    cfg = get_smoke_config(arch).replace(dtype="float32")
    params = jax.eval_shape(lambda: tr.init_params(cfg, jax.random.PRNGKey(0)))
    devs = np.array(jax.devices() * 8)[:8].reshape(2, 4)
    mesh = jax.sharding.Mesh(devs, ("data", "model"))
    specs = param_specs(params, cfg, mesh)
    _check_divisibility(params, specs, mesh)


def test_mesh_axes_both_meshes():
    devs = np.array(jax.devices() * 8)[:8]
    m1 = jax.sharding.Mesh(devs.reshape(2, 4), ("data", "model"))
    assert mesh_axes(m1) == (("data",), "model")
    m2 = jax.sharding.Mesh(devs.reshape(2, 2, 2), ("pod", "data", "model"))
    assert mesh_axes(m2) == (("pod", "data"), "model")


@pytest.mark.parametrize("shape_name", list(SHAPES))
def test_input_specs_exist_for_supported(shape_name):
    for arch in ARCH_IDS:
        from repro.configs.registry import get_config
        cfg = get_config(arch)
        ok, _ = supported(cfg, shape_name)
        if not ok:
            continue
        specs = input_specs(cfg, shape_name)
        assert "params" in specs
        mode = mode_of(shape_name)
        if mode == "train":
            S, B = SHAPES[shape_name]
            lead = specs["batch"]["labels"].shape
            assert lead[0] == B
        elif mode == "decode":
            assert specs["tokens"].shape[1] == 1
            assert "cache" in specs


def test_skip_table_counts():
    """DESIGN.md: 10 + 10 + 9 + 4 = 33 live pairs."""
    from repro.configs.registry import get_config
    live = sum(supported(get_config(a), s)[0]
               for a in ARCH_IDS for s in SHAPES)
    assert live == 33


def test_sharded_train_step_on_host_mesh():
    """jit with in_shardings on the 1-device host mesh compiles + runs."""
    cfg = get_smoke_config("qwen2-7b").replace(dtype="float32")
    mesh = make_host_mesh()
    params = tr.init_params(cfg, jax.random.PRNGKey(0))
    batch = smoke_batch(cfg, 2, 8)
    from repro.optim import adamw, constant
    from repro.sharding.specs import to_shardings
    opt = adamw(constant(1e-3))
    opt_state = opt.init(params)
    with mesh:
        pspecs = param_specs(params, cfg, mesh)
        bspecs = batch_specs(batch, cfg, mesh)

        def step(p, s, b):
            (loss, _), grads = jax.value_and_grad(
                tr.loss_fn, has_aux=True)(p, cfg, b)
            p, s = opt.update(grads, s, p)
            return p, s, loss

        jitted = jax.jit(step, in_shardings=(
            to_shardings(pspecs, mesh), None,
            to_shardings(bspecs, mesh)))
        p2, s2, loss = jitted(params, opt_state, batch)
    assert np.isfinite(float(loss))


def test_cache_specs_cover_every_family():
    devs = np.array(jax.devices() * 8)[:8].reshape(2, 4)
    mesh = jax.sharding.Mesh(devs, ("data", "model"))
    for arch in ["qwen2-7b", "mamba2-2.7b", "zamba2-1.2b",
                 "deepseek-v3-671b", "mixtral-8x7b"]:
        cfg = get_smoke_config(arch).replace(dtype="float32")
        cache = jax.eval_shape(lambda c=cfg: tr.init_cache(c, 8, 64))
        specs = cache_specs(cache, cfg, mesh)
        _check_divisibility(cache, specs, mesh)
