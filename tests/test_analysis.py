"""The analyzers analyzed: fixture snippets for every violation class,
plus the repo-wide gate.

Each checker is exercised twice per rule: a known-bad fixture (string
source compiled via ``ast.parse``) asserted to be *caught*, and a clean
twin asserted to be *silent* — the five violation classes the ISSUE
names (lock violation, wall-clock call, missing unit suffix,
digest-fold mismatch, pack/unpack drift) each appear as an explicit
fixture. The ``analysis``-marked tests at the bottom run the real gate
over the repo: ``src/`` must be green against the checked-in baseline,
``core/fleet/`` must be green *without* any baseline, and
``benchmarks/fleet_sim.py``'s wall-vs-virtual timing split must stay
pinned to its two justified allow-marker lines.
"""
from __future__ import annotations

import ast
import json
import os
import subprocess
import sys
import textwrap

import pytest

from repro.analysis import (BaselineEntry, apply_baseline, run_analysis)
from repro.analysis.baseline import load_baseline
from repro.analysis.concurrency import check_concurrency
from repro.analysis.contracts import (check_digest_fold, check_pack_unpack,
                                      check_unit_suffixes)
from repro.analysis.purity import check_purity, marker_lines
from repro.analysis.registry import ClosureVar, SharedAttr

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BASELINE = os.path.join(REPO, "analysis_baseline.json")


def _parse(src: str):
    src = textwrap.dedent(src)
    return ast.parse(src), src.splitlines()


def _rules(findings):
    return [f.rule for f in findings]


# ---------------------------------------------------------------------------
# concurrency: lock discipline
# ---------------------------------------------------------------------------
THREADED_BAD = """
    import threading

    class Engine:
        def __init__(self):
            self._lock = threading.Lock()
            self.count = 0

        def start(self):
            threading.Thread(target=self._loop).start()

        def _loop(self):
            self.count += 1          # write without the lock
"""

THREADED_GOOD = """
    import threading

    class Engine:
        def __init__(self):
            self._lock = threading.Lock()
            self.count = 0

        def start(self):
            threading.Thread(target=self._loop).start()

        def _loop(self):
            with self._lock:
                self.count += 1
"""


def test_lock_violation_caught():
    tree, _ = _parse(THREADED_BAD)
    reg = (SharedAttr("Engine", "count", lock="_lock"),)
    findings = check_concurrency(tree, "x.py", reg)
    assert _rules(findings) == ["lock-discipline"]
    assert "Engine.count" in findings[0].symbol


def test_lock_guarded_clean():
    tree, _ = _parse(THREADED_GOOD)
    reg = (SharedAttr("Engine", "count", lock="_lock"),)
    assert check_concurrency(tree, "x.py", reg) == []


def test_unregistered_thread_write_caught():
    tree, _ = _parse(THREADED_BAD)
    findings = check_concurrency(tree, "x.py", ())
    assert _rules(findings) == ["unguarded-shared-write"]


def test_init_writes_exempt():
    # __init__ publishes before any thread exists: never flagged
    tree, _ = _parse(THREADED_GOOD)
    findings = check_concurrency(
        tree, "x.py", (SharedAttr("Engine", "_lock", lock="_lock"),))
    assert findings == []


def test_subscript_store_caught():
    tree, _ = _parse("""
        import threading

        class Bank:
            def start(self):
                threading.Thread(target=self._loop).start()

            def _loop(self):
                self.cache[0] = 1
    """)
    reg = (SharedAttr("Bank", "cache", lock="_lock"),)
    findings = check_concurrency(tree, "x.py", reg)
    assert "lock-discipline" in _rules(findings)
    # the lock itself is also stale (never assigned) — drift detection
    assert "stale-registry" in _rules(findings)


def test_thread_reachability_transitive():
    # a write two self-calls away from the thread entry is still flagged
    tree, _ = _parse("""
        import threading

        class Deep:
            def start(self):
                threading.Thread(target=self._entry).start()

            def _entry(self):
                self._step()

            def _step(self):
                self.state = 1
    """)
    findings = check_concurrency(tree, "x.py", ())
    assert _rules(findings) == ["unguarded-shared-write"]
    assert findings[0].symbol == "Deep.state"


def test_closure_var_lock_rule():
    bad = """
        import threading

        def serve(stats=None):
            lock = threading.Lock()

            def _worker():
                stats["n"] = stats.get("n", 0) + 1

            threading.Thread(target=_worker).start()
    """
    tree, _ = _parse(bad)
    reg = (ClosureVar("serve", "stats", lock="lock"),)
    findings = check_concurrency(tree, "x.py", reg)
    assert _rules(findings) == ["lock-discipline"]
    good = bad.replace('stats["n"] = stats.get("n", 0) + 1',
                       'with lock:\n'
                       '                    stats["n"] = 1')
    tree, _ = _parse(good)
    assert check_concurrency(tree, "x.py", reg) == []


def test_stale_registry_class_and_attr():
    tree, _ = _parse(THREADED_GOOD)
    findings = check_concurrency(tree, "x.py", (
        SharedAttr("Gone", "count", lock="_lock"),
        SharedAttr("Engine", "vanished", lock="_lock")))
    assert _rules(findings).count("stale-registry") == 2


def test_ownership_requires_justification():
    tree, _ = _parse(THREADED_GOOD)
    findings = check_concurrency(
        tree, "x.py", (SharedAttr("Engine", "count", lock=None, note=""),))
    assert "registry-justification" in _rules(findings)
    findings = check_concurrency(
        tree, "x.py",
        (SharedAttr("Engine", "count", lock=None, note="single owner"),))
    assert findings == []


# ---------------------------------------------------------------------------
# purity: wall clock and ambient randomness
# ---------------------------------------------------------------------------
def test_wallclock_call_caught():
    tree, lines = _parse("""
        import time

        def tick(q):
            return time.time() - q
    """)
    findings = check_purity(tree, "x.py", lines)
    assert _rules(findings) == ["purity"]
    assert "time.time" in findings[0].message


def test_sleep_and_monotonic_caught():
    tree, lines = _parse("""
        import time

        def nap():
            time.sleep(0.1)
            return time.monotonic()
    """)
    assert len(check_purity(tree, "x.py", lines)) == 2


def test_module_random_caught_seeded_rng_clean():
    tree, lines = _parse("""
        import random

        def draw():
            return random.random()
    """)
    assert _rules(check_purity(tree, "x.py", lines)) == ["purity"]
    tree, lines = _parse("""
        import random

        def draw(seed):
            rng = random.Random(seed)
            return rng.random()
    """)
    assert check_purity(tree, "x.py", lines) == []


def test_np_random_convenience_caught_generator_clean():
    tree, lines = _parse("""
        import numpy as np

        def draw():
            return np.random.rand(3)
    """)
    assert _rules(check_purity(tree, "x.py", lines)) == ["purity"]
    tree, lines = _parse("""
        import numpy as np

        def draw(seed):
            return np.random.default_rng(seed).random(3)
    """)
    assert check_purity(tree, "x.py", lines) == []


def test_purity_class_scope_filter():
    src = """
        import time

        def outside():
            return time.time()      # not in the scanned class: ignored

        class Sim:
            def step(self):
                return time.monotonic()
    """
    tree, lines = _parse(src)
    findings = check_purity(tree, "x.py", lines, class_filter=("Sim",))
    assert len(findings) == 1 and findings[0].symbol == "Sim.step"


def test_allow_marker_needs_justification():
    justified = """
        import time

        def bench():
            return time.perf_counter()  # wall-clock: sweep timing only
    """
    tree, lines = _parse(justified)
    assert check_purity(tree, "x.py", lines) == []
    bare = justified.replace("# wall-clock: sweep timing only",
                             "# wall-clock:")
    tree, lines = _parse(bare)
    assert _rules(check_purity(tree, "x.py", lines)) == ["purity"]


# ---------------------------------------------------------------------------
# contracts: unit suffixes, digest fold, pack/unpack
# ---------------------------------------------------------------------------
def test_missing_unit_suffix_caught():
    tree, _ = _parse("""
        class Policy:
            def to_json(self):
                return {"upload_wait": self.w, "max_batch": 4}
    """)
    findings = check_unit_suffixes(tree, "x.py", ["Policy"])
    assert _rules(findings) == ["unit-suffix"]
    assert "upload_wait" in findings[0].symbol


def test_unit_and_dimensionless_suffixes_clean():
    tree, _ = _parse("""
        class Policy:
            def to_json(self):
                return {"max_wait_ms": 1, "battery_j": 2,
                        "backoff_jitter": 0.1, "latency_weight": 1.0,
                        "base_rate_hz": 5.0, "seed": 7}
    """)
    assert check_unit_suffixes(tree, "x.py", ["Policy"]) == []


def test_unit_suffix_registry_drift():
    tree, _ = _parse("class Other:\n    pass\n")
    findings = check_unit_suffixes(tree, "x.py", ["Policy", "Other"])
    assert _rules(findings).count("stale-registry") == 2   # missing class
    # ... and a present class without to_json


DIGEST_BAD = """
    class Plan:
        def contract(self):
            doc = {"split": self.split}
            doc["energy"] = self.energy.to_json()   # unguarded fold
            if self.batching is not None:
                doc["batching"] = self.batching.to_json()
            return doc
"""


def test_digest_fold_mismatch_caught():
    tree, _ = _parse(DIGEST_BAD)
    findings = check_digest_fold(tree, "x.py", "Plan", "contract",
                                 ["energy", "batching"])
    assert _rules(findings) == ["digest-fold"]
    assert "energy" in findings[0].symbol


def test_digest_fold_guarded_clean_and_missing_section():
    tree, _ = _parse(DIGEST_BAD)
    findings = check_digest_fold(tree, "x.py", "Plan", "contract",
                                 ["batching", "faults"])
    assert _rules(findings) == ["digest-fold"]       # faults never folded
    assert "faults" in findings[0].symbol


def test_pack_unpack_drift_caught():
    tree, _ = _parse("""
        import struct

        def enc(a, b):
            return struct.pack("<II", a, b)

        def dec(buf):
            return struct.unpack("<I", buf)      # drifted: one field
    """)
    findings = check_pack_unpack(tree, "x.py")
    assert _rules(findings) == ["pack-unpack"]
    assert "<II" in findings[0].symbol


def test_pack_unpack_fstring_normalized_clean():
    tree, _ = _parse("""
        import struct

        def enc(arr):
            return struct.pack(f"<{arr.ndim}Q", *arr.shape)

        def dec(buf, ndim):
            return struct.unpack_from(f"<{ndim}Q", buf, 0)
    """)
    assert check_pack_unpack(tree, "x.py") == []


def test_struct_var_pack_without_unpack_caught():
    tree, _ = _parse("""
        from struct import Struct
        HDR = Struct("<IH")

        def enc(v):
            return HDR.pack(1, v)
    """)
    findings = check_pack_unpack(tree, "x.py")
    assert _rules(findings) == ["pack-unpack"]
    assert findings[0].symbol == "HDR"


# ---------------------------------------------------------------------------
# baseline semantics
# ---------------------------------------------------------------------------
def _one_finding():
    tree, lines = _parse("import time\nt = time.time()\n")
    return check_purity(tree, "x.py", lines)


def test_baseline_suppresses_with_justification():
    findings = _one_finding()
    entry = BaselineEntry("purity", "x.py", findings[0].symbol,
                          justification="known demo-mode clock read")
    unsuppressed, suppressed = apply_baseline(findings, [entry])
    assert unsuppressed == [] and len(suppressed) == 1


def test_baseline_without_justification_is_a_finding():
    findings = _one_finding()
    entry = BaselineEntry("purity", "x.py", findings[0].symbol)
    unsuppressed, _ = apply_baseline(findings, [entry])
    rules = _rules(unsuppressed)
    assert "purity" in rules and "baseline-justification" in rules


def test_stale_suppression_is_a_finding():
    entry = BaselineEntry("purity", "gone.py", "Gone.symbol",
                          justification="was fixed long ago")
    unsuppressed, _ = apply_baseline([], [entry])
    assert _rules(unsuppressed) == ["stale-suppression"]


def test_partial_scan_cannot_declare_staleness():
    """A run that never analyzed an entry's file must not call the
    entry stale — only a scan covering that path decides."""
    entry = BaselineEntry("purity", "src/a.py", "A.m",
                          justification="single-owner demo path")
    unsuppressed, _ = apply_baseline([], [entry],
                                     scanned_paths={"src/other.py"})
    assert unsuppressed == []
    unsuppressed, _ = apply_baseline([], [entry],
                                     scanned_paths={"src/a.py"})
    assert _rules(unsuppressed) == ["stale-suppression"]


@pytest.mark.analysis
def test_fleet_benchmark_partial_run_with_real_baseline():
    """The CI step analyzes benchmarks/fleet_sim.py alone against the
    checked-in baseline: the SimChannel entry's file is out of scope
    for that run, so it must not surface as a stale suppression."""
    report = run_analysis([os.path.join(REPO, "benchmarks",
                                        "fleet_sim.py")],
                          baseline_path=BASELINE)
    assert report.ok, report.render()


# ---------------------------------------------------------------------------
# the repo-wide gate
# ---------------------------------------------------------------------------
@pytest.mark.analysis
def test_repo_gate_green_with_baseline():
    """`python -m repro.analysis` semantics: src/ has zero unsuppressed
    findings against the checked-in baseline."""
    report = run_analysis([os.path.join(REPO, "src")],
                          baseline_path=BASELINE)
    assert report.ok, "unsuppressed findings:\n" + report.render()
    assert report.n_files > 50


@pytest.mark.analysis
def test_baseline_entries_all_justified():
    for entry in load_baseline(BASELINE):
        assert entry.justification.strip(), f"unjustified: {entry}"


@pytest.mark.analysis
def test_fleet_tree_pure_without_baseline():
    """core/fleet/ determinism is checker-clean with NO suppressions —
    the bit-identity contract rides on this."""
    report = run_analysis(
        [os.path.join(REPO, "src", "repro", "core", "fleet")], entries=[])
    purity = [f for f in report.findings if f.rule == "purity"]
    assert purity == [], "\n".join(f.render() for f in purity)


@pytest.mark.analysis
def test_fleet_benchmark_wall_clock_pinned():
    """benchmarks/fleet_sim.py: exactly its two sweep-timing lines carry
    justified wall-clock markers; everything else is virtual-clock
    pure. Moving a wall read elsewhere breaks this test."""
    path = os.path.join(REPO, "benchmarks", "fleet_sim.py")
    report = run_analysis([path], entries=[])
    assert report.ok, report.render()
    with open(path) as f:
        lines = f.read().splitlines()
    markers = marker_lines(lines)
    assert len(markers) == 2, markers
    for lineno, _ in markers:
        assert "perf_counter" in lines[lineno - 1]


@pytest.mark.analysis
def test_cli_json_report():
    """The CLI exits 0 on src/ and emits a well-formed JSON report."""
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--json"],
        capture_output=True, text=True,
        env={**os.environ, "PYTHONPATH": os.path.join(REPO, "src")})
    assert proc.returncode == 0, proc.stdout + proc.stderr
    doc = json.loads(proc.stdout)
    assert doc["ok"] is True
    assert doc["findings"] == []
    assert {f["rule"] for f in doc["suppressed"]} <= {"purity"}
