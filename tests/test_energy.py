"""Energy-aware split optimization: the (T, E) pricing model, the
weighted objective and Pareto reporter, battery-aware adaptive control,
plan digest semantics for the ``energy`` section, and e_edge_j result
parity across the three serving backends."""
from __future__ import annotations

import jax
import numpy as np
import pytest

from repro import serving
from repro.core.partition.energy_model import (EnergyPolicy, EnergyProfile,
                                               MCU_ENERGY, PI_ENERGY,
                                               RadioProfile, pareto_front,
                                               split_energy)
from repro.core.partition.latency_model import (cnn_input_bytes,
                                                compacted_cnn_layer_costs,
                                                wire_tx_scale)
from repro.core.partition.profiles import (LinkProfile, MCU_EDGE,
                                           PAPER_PROFILE, TraceSegment,
                                           TwoTierProfile)
from repro.core.partition.splitter import (energy_aware_split, greedy_split,
                                           sweep_splits)
from repro.core.collab.adaptive import AdaptivePolicy, AdaptiveSplitController
from repro.core.pruning.masks import cnn_masks_from_ratios
from repro.models.cnn import init_cnn_params, prunable_layers, tiny_cnn_config


@pytest.fixture(scope="module")
def setup():
    cfg = tiny_cnn_config(num_classes=7, hw=32)
    params = init_cnn_params(jax.random.PRNGKey(0), cfg)
    masks = cnn_masks_from_ratios(
        params, cfg, {i: 0.5 for i in prunable_layers(cfg)})
    costs = compacted_cnn_layer_costs(cfg, masks)
    x = np.asarray(jax.random.normal(jax.random.PRNGKey(1), (1, 32, 32, 3)),
                   np.float32)
    return cfg, params, masks, costs, x


def mcu_profile(mbps=50.0, rtt_s=1e-3) -> TwoTierProfile:
    return TwoTierProfile(MCU_EDGE, PAPER_PROFILE.server,
                          LinkProfile("test", bandwidth=mbps * 1e6 / 8,
                                      rtt_s=rtt_s))


def _tx_scale(cfg, masks):
    return lambda c: wire_tx_scale(cfg, masks, c, codec="fp32", compact=True)


# ---------------------------------------------------------------------------
# the pricing formula
# ---------------------------------------------------------------------------
def test_energy_breakdown_arithmetic():
    """Hand-checked joules: TX active time excludes the RTT, which is
    billed as waiting together with the server time."""
    prof = EnergyProfile("dev", compute_power_w=2.0, idle_power_w=0.5,
                         radio=RadioProfile("r", tx_power_w=1.0,
                                            rx_power_w=0.25,
                                            idle_power_w=0.1))
    br = prof.energy_breakdown(t_device=1.0, t_tx=0.3, t_server=0.2,
                               rtt_s=0.1)
    assert br["e_comp_j"] == pytest.approx(1.0 * (2.0 + 0.1))
    assert br["e_tx_j"] == pytest.approx(0.2 * 1.0)      # 0.3 - RTT 0.1
    assert br["e_wait_j"] == pytest.approx((0.1 + 0.2) * (0.5 + 0.25))
    assert br["e_edge_j"] == pytest.approx(
        br["e_comp_j"] + br["e_tx_j"] + br["e_wait_j"])
    # no-transmission request: everything is compute
    br0 = prof.energy_breakdown(1.0, 0.0, 0.0, rtt_s=0.1)
    assert br0["e_tx_j"] == 0.0 and br0["e_wait_j"] == 0.0


def test_negative_power_rejected():
    with pytest.raises(ValueError, match=">= 0"):
        RadioProfile("r", tx_power_w=-1.0, rx_power_w=0.1)
    with pytest.raises(ValueError, match=">= 0"):
        EnergyProfile("d", compute_power_w=-0.1, idle_power_w=0.0,
                      radio=MCU_ENERGY.radio)
    with pytest.raises(ValueError, match="battery_j"):
        EnergyPolicy(profile=MCU_ENERGY, battery_j=0.0)
    with pytest.raises(ValueError, match="weights"):
        EnergyPolicy(profile=MCU_ENERGY, energy_weight_s_per_j=-1.0)


def test_sweep_rows_carry_energy_columns(setup):
    cfg, _, masks, costs, _ = setup
    prof = mcu_profile()
    tab = sweep_splits(costs, prof, cnn_input_bytes(cfg), energy=MCU_ENERGY,
                       tx_scale=_tx_scale(cfg, masks))
    for row in tab:
        for key in ("E_comp", "E_tx", "E_wait", "E_edge"):
            assert key in row and row[key] >= 0.0
        assert row["E_edge"] == pytest.approx(
            row["E_comp"] + row["E_tx"] + row["E_wait"])
        # the per-row pricing equals the single-split entry point
        solo = split_energy(costs, int(row["split"]), prof, MCU_ENERGY,
                            cnn_input_bytes(cfg),
                            tx_scale=_tx_scale(cfg, masks)(int(row["split"])))
        assert solo["E_edge"] == pytest.approx(row["E_edge"])
    # all-edge split: no TX, no wait
    last = tab[-1]
    assert last["split"] == len(costs)
    assert last["E_tx"] == 0.0 and last["E_wait"] == 0.0
    # the paper-edge profile prices the cloud for completeness
    tab_cloud = sweep_splits(costs, prof, cnn_input_bytes(cfg),
                             energy=serving.PAPER_EDGE_ENERGY)
    assert all("E_cloud" in r for r in tab_cloud)
    assert tab_cloud[-1]["E_cloud"] == 0.0          # nothing runs remotely


# ---------------------------------------------------------------------------
# the weighted objective + Pareto front
# ---------------------------------------------------------------------------
def test_zero_weight_degenerates_to_greedy(setup):
    cfg, _, masks, costs, _ = setup
    prof = mcu_profile()
    pol = EnergyPolicy(profile=MCU_ENERGY, energy_weight_s_per_j=0.0)
    kw = dict(tx_scale=_tx_scale(cfg, masks))
    assert (energy_aware_split(costs, prof, cnn_input_bytes(cfg), pol,
                               **kw).split_point
            == greedy_split(costs, prof, cnn_input_bytes(cfg),
                            **kw).split_point)


def test_energy_objective_flips_split(setup):
    """Acceptance regime: on the MCU class at 50 Mbps / 1 ms RTT the
    latency argmin offloads but the weighted objective keeps more
    layers on the device (the radio is the expensive peripheral)."""
    cfg, _, masks, costs, _ = setup
    prof = mcu_profile()
    pol = EnergyPolicy(profile=MCU_ENERGY, energy_weight_s_per_j=0.5)
    kw = dict(tx_scale=_tx_scale(cfg, masks))
    t_pick = greedy_split(costs, prof, cnn_input_bytes(cfg), **kw)
    e_pick = energy_aware_split(costs, prof, cnn_input_bytes(cfg), pol, **kw)
    assert e_pick.split_point != t_pick.split_point
    t_row = next(r for r in e_pick.table
                 if r["split"] == t_pick.split_point)
    assert e_pick.latency["E_edge"] < t_row["E_edge"]


def test_pareto_front_monotone(setup):
    cfg, _, masks, costs, _ = setup
    for mbps in (50.0, 5.0):
        tab = sweep_splits(costs, mcu_profile(mbps), cnn_input_bytes(cfg),
                           energy=MCU_ENERGY, tx_scale=_tx_scale(cfg, masks))
        front = pareto_front(tab)
        assert front, "empty Pareto front"
        ts = [r["T"] for r in front]
        es = [r["E_edge"] for r in front]
        assert ts == sorted(ts)                      # T ascending
        assert all(a > b for a, b in zip(es, es[1:]))  # E strictly down
        # endpoints: the latency argmin and the energy argmin survive
        assert front[0]["T"] == min(r["T"] for r in tab)
        assert front[-1]["E_edge"] == min(r["E_edge"] for r in tab)
        # nothing on the front is dominated by any table row
        for f in front:
            assert not any(r["T"] <= f["T"] and r["E_edge"] < f["E_edge"]
                           for r in tab)


# ---------------------------------------------------------------------------
# degenerate links and battery exhaustion
# ---------------------------------------------------------------------------
def test_trace_rejects_zero_bandwidth_segment():
    """An outage must be modeled as a tiny positive bandwidth, never 0
    (byte-draining loops would spin forever)."""
    from repro.core.partition.profiles import LinkTrace
    with pytest.raises(ValueError, match="bandwidth > 0"):
        LinkTrace("dead", (TraceSegment(1.0, 0.0),))


def test_near_zero_bandwidth_forces_all_edge(setup):
    """Under an outage segment (1 kbit/s) both seconds and joules of any
    transmitting split explode, so the energy objective lands on the
    all-edge split."""
    cfg, _, masks, costs, _ = setup
    prof = mcu_profile(mbps=0.001)                   # 1 kbit/s outage
    pol = EnergyPolicy(profile=MCU_ENERGY, energy_weight_s_per_j=0.5)
    pick = energy_aware_split(costs, prof, cnn_input_bytes(cfg), pol,
                              tx_scale=_tx_scale(cfg, masks))
    n = len(costs)
    assert pick.split_point == n
    offload = next(r for r in pick.table if r["split"] == 0)
    all_edge = next(r for r in pick.table if r["split"] == n)
    assert offload["E_edge"] > 100 * all_edge["E_edge"]


def _controller(setup, energy, split=0, candidates=(0, 3, 13),
                hysteresis=0.01, dwell=1):
    cfg, _, masks, costs, _ = setup
    return AdaptiveSplitController(
        costs, mcu_profile(), cnn_input_bytes(cfg),
        AdaptivePolicy(candidates=candidates, ewma_alpha=0.5,
                       min_samples=2, hysteresis=hysteresis, dwell=dwell),
        split, tx_scale=_tx_scale(cfg, masks), energy=energy)


def test_battery_exhaustion_forces_min_energy_split(setup):
    """Draining the budget to zero maxes the urgency weight: the
    controller must land on the candidate with minimum joules (all-edge
    on the MCU class) and report an empty battery."""
    pol = EnergyPolicy(profile=MCU_ENERGY, energy_weight_s_per_j=0.05,
                       battery_j=0.01)
    ctl = _controller(setup, pol)
    bw = 50e6 / 8
    t_tx = 6000 / bw + 1e-3
    for _ in range(4):
        ctl.step(6000, t_tx, e_edge_j=0.004)         # 4 mJ per request
    assert ctl.battery_j == 0.0 and ctl.battery_fraction == 0.0
    assert ctl.history, "exhausted battery never forced a switch"
    table = ctl.sweep(ctl.estimator.bandwidth)
    emin = min(table, key=lambda r: r["E_edge"])
    assert ctl.split == int(emin["split"])
    # every switch recorded the battery level it was decided at
    assert all(sw.battery_j is not None for sw in ctl.history)


def test_full_battery_keeps_latency_choice(setup):
    """With a full battery and a small static weight, the controller
    stays at (or moves to) the latency optimum — urgency scaling only
    kicks in as the budget drains."""
    pol = EnergyPolicy(profile=MCU_ENERGY, energy_weight_s_per_j=0.05,
                       battery_j=1000.0)
    ctl = _controller(setup, pol)
    bw = 50e6 / 8
    t_tx = 6000 / bw + 1e-3
    for _ in range(4):
        ctl.step(6000, t_tx, e_edge_j=1e-6)
    table = ctl.sweep(ctl.estimator.bandwidth)
    tmin = min(table, key=lambda r: r["T"])
    assert ctl.split == int(tmin["split"])


def test_unmetered_controller_scores_latency_only(setup):
    ctl = _controller(setup, energy=None)
    row = {"T": 1.0, "E_edge": 99.0}
    assert ctl._score(row) == 1.0
    ctl.drain(5.0)                                   # no-op, no battery
    assert ctl.battery_j is None and ctl.battery_fraction is None


# ---------------------------------------------------------------------------
# plan digest semantics + session plumbing parity
# ---------------------------------------------------------------------------
def make_plan(setup, port=29530, **kw):
    cfg, params, masks, _, _ = setup
    kw.setdefault("split", 6)
    return serving.DeploymentPlan.from_args(
        params, cfg, masks=masks, compact=True, codec="fp32",
        shape_link=False, port=port, **kw)


def test_digest_stable_without_energy_section(setup):
    plain = make_plan(setup)
    assert "energy" not in plain.contract()
    metered = make_plan(setup, energy=EnergyPolicy(profile=MCU_ENERGY))
    assert "energy" in metered.contract()
    assert plain.digest != metered.digest
    # metering knobs are contract: a different battery → different digest
    budget = make_plan(setup, energy=EnergyPolicy(profile=MCU_ENERGY,
                                                  battery_j=5.0))
    assert budget.digest != metered.digest
    # un-metered plans are digest-identical to a freshly built twin
    assert plain.digest == make_plan(setup).digest


def test_energy_plan_save_load_roundtrip(setup, tmp_path):
    pol = EnergyPolicy(profile=PI_ENERGY, energy_weight_s_per_j=2.0,
                       battery_j=3.5)
    plan = make_plan(setup, energy=pol)
    loaded = serving.DeploymentPlan.load(plan.save(str(tmp_path / "d")))
    assert loaded.digest == plan.digest
    assert loaded.energy == pol


def test_e_edge_j_parity_across_backends(setup):
    """Result-dict normalization: all three backends report the same
    key set on a metered plan, with a positive joules figure, and the
    local figure matches the analytic split_energy row exactly (same
    formula, same inputs)."""
    cfg, _, masks, costs, x = setup
    plan = make_plan(setup, port=29531,
                     energy=EnergyPolicy(profile=MCU_ENERGY),
                     profile=mcu_profile())
    keysets, results = [], {}
    local = serving.connect(plan, backend="local").infer(x)
    results["local"] = local
    with serving.CloudServer(plan):
        with serving.connect(plan, backend="socket") as sess:
            results["socket"] = sess.infer(x)
    stream_sess = serving.connect(plan, backend="streaming",
                                  realtime_channel=False)
    results["streaming"] = stream_sess.infer(x)
    for name, res in results.items():
        assert set(res) == {"logits", "t_edge", "t_upstream", "t_total",
                            "tx_bytes", "e_edge_j", "fault"}, name
        assert res["e_edge_j"] is not None and res["e_edge_j"] > 0, name
    assert (results["local"]["tx_bytes"] == results["socket"]["tx_bytes"]
            == results["streaming"]["tx_bytes"])
    analytic = split_energy(costs, plan.split, plan.profile, MCU_ENERGY,
                            cnn_input_bytes(cfg),
                            tx_scale=_tx_scale(cfg, masks)(plan.split))
    # the measured frame carries a few tens of codec-header bytes the
    # analytic model deliberately does not price — sub-percent here
    assert local["e_edge_j"] == pytest.approx(analytic["E_edge"], rel=5e-3)


def test_streaming_microbatch_energy_keeps_tx_active(setup):
    """A micro-batched frame pays ONE RTT shared across its requests;
    the per-request energy pricing must amortize the peeled RTT the
    same way, so radio-active TX time stays > 0 (regression: peeling a
    full RTT per request zeroed e_tx_j for microbatch > 1)."""
    _, _, _, _, x = setup
    plan = make_plan(setup, port=29532,
                     energy=EnergyPolicy(profile=MCU_ENERGY),
                     profile=mcu_profile())
    sess = serving.connect(plan, backend="streaming",
                           realtime_channel=False, microbatch=4,
                           queue_depth=8)
    res = sess.infer_many([x] * 16)
    assert all(r["e_edge_j"] > 0 for r in res)
    rtt = plan.profile.link.rtt_s
    rep = sess.last_report
    assert any(r["frame_n"] > 1 for r in rep.results), \
        "stream never micro-batched; the regression path was not hit"
    for r in rep.results:
        assert r["t_tx_model"] - rtt / r["frame_n"] > 0, \
            "per-request modeled TX cost fell below its RTT share"


def test_local_session_drains_battery_and_resplits(setup):
    """End-to-end battery story through the serving API: a metered
    adaptive plan re-splits toward lower joules as its budget drains."""
    cfg, params, masks, _, x = setup
    pol = EnergyPolicy(profile=MCU_ENERGY, energy_weight_s_per_j=0.1,
                       battery_j=0.05)
    plan = serving.DeploymentPlan.from_args(
        params, cfg, 0, masks=masks, compact=True, codec="fp32",
        shape_link=False, profile=mcu_profile(), energy=pol,
        adaptive=serving.AdaptivePolicy(candidates=(0, 3, 13),
                                        ewma_alpha=0.5, min_samples=2,
                                        hysteresis=0.01, dwell=2))
    sess = serving.connect(plan, backend="local")
    for _ in range(40):
        res = sess.infer(x)
        assert res["e_edge_j"] > 0
    assert sess.switches, "battery drain never re-split"
    for sw in sess.switches:
        assert sw.predicted_E < sw.current_E
    assert sess._controller.battery_j < pol.battery_j
