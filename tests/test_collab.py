"""Collaborative split-inference runtime: in-process runner, real localhost
sockets, bandwidth shaping, tensor framing."""
from __future__ import annotations

import threading
import time

import jax
import numpy as np
import pytest

from repro.core.collab.channel import SimChannel
from repro.core.collab.protocol import decode_tensor, encode_tensor
from repro.core.collab.runtime import CollabRunner, EdgeClient, serve_cloud
from repro.core.partition.profiles import PAPER_PROFILE, LinkProfile
from repro.models.cnn import cnn_apply, init_cnn_params, tiny_cnn_config


@pytest.fixture(scope="module")
def cnn_setup():
    cfg = tiny_cnn_config(num_classes=7, hw=32)
    params = init_cnn_params(jax.random.PRNGKey(0), cfg)
    x = np.asarray(jax.random.normal(jax.random.PRNGKey(1), (2, 32, 32, 3)))
    return cfg, params, x


def test_protocol_roundtrip():
    for dtype in (np.float32, np.int32, np.float16):
        arr = np.random.RandomState(0).rand(3, 5, 7).astype(dtype)
        buf = encode_tensor(arr)
        out, meta = decode_tensor(buf)
        np.testing.assert_array_equal(arr, out)
        assert out.dtype == dtype


def test_sim_channel_accounts_bytes_and_time():
    ch = SimChannel(LinkProfile("test", bandwidth=1e6, rtt_s=0.01))
    t = ch.send(500_000)
    assert abs(t - 0.51) < 1e-9
    assert ch.sent_bytes == 500_000


@pytest.mark.parametrize("split_frac", [0.0, 0.5, 1.0])
def test_collab_runner_logits_equal_monolithic(cnn_setup, split_frac):
    """Split execution at any point returns the monolithic logits."""
    cfg, params, x = cnn_setup
    n = len(cfg.layers)
    split = int(round(split_frac * n))
    runner = CollabRunner(params, cfg, split, PAPER_PROFILE)
    res = runner.infer(x)
    want = np.asarray(cnn_apply(params, cfg, x))
    np.testing.assert_allclose(res["logits"], want, rtol=1e-5, atol=1e-5)
    t = res["timing"]
    assert t.total == t.t_device + t.t_tx + t.t_server
    if 0 < split < n:
        assert t.tx_bytes > 0


def test_collab_runner_masked(cnn_setup):
    import jax.numpy as jnp
    cfg, params, x = cnn_setup
    masks = {0: jnp.asarray(np.r_[np.ones(8), np.zeros(
        cfg.layers[0].out_channels - 8)].astype(np.float32))}
    runner = CollabRunner(params, cfg, 4, PAPER_PROFILE, masks=masks)
    want = np.asarray(cnn_apply(params, cfg, x, masks=masks))
    np.testing.assert_allclose(runner.infer(x)["logits"], want,
                               rtol=1e-5, atol=1e-5)


def test_socket_deployment_roundtrip(cnn_setup):
    """Real edge/cloud pair over localhost TCP (paper §4.3 deployment)."""
    cfg, params, x = cnn_setup
    split, port = 4, 29471
    ready = threading.Event()
    srv = threading.Thread(target=serve_cloud,
                           args=(params, cfg, split, port),
                           kwargs=dict(max_requests=2, ready=ready),
                           daemon=True)
    srv.start()
    assert ready.wait(10)
    client = EdgeClient(params, cfg, split, port)
    want = np.asarray(cnn_apply(params, cfg, x))
    for _ in range(2):
        res = client.infer(x)
        np.testing.assert_allclose(res["logits"], want, rtol=1e-5,
                                   atol=1e-5)
        assert res["tx_bytes"] > 0
    client.close()
    srv.join(10)
    assert not srv.is_alive()


def test_shaped_socket_paces_traffic(cnn_setup):
    """Token-bucket shaping: ~0.8 MB over a 8 MB/s link takes >= 80 ms."""
    cfg, params, x = cnn_setup
    link = LinkProfile("slow", bandwidth=8e6)
    split, port = 2, 29473
    ready = threading.Event()
    srv = threading.Thread(target=serve_cloud,
                           args=(params, cfg, split, port),
                           kwargs=dict(max_requests=1, ready=ready,
                                       link=link),
                           daemon=True)
    srv.start()
    assert ready.wait(10)
    client = EdgeClient(params, cfg, split, port, link=link)
    t0 = time.perf_counter()
    res = client.infer(np.repeat(x, 8, axis=0))       # bigger payload
    elapsed = time.perf_counter() - t0
    expect = res["tx_bytes"] / link.bandwidth
    assert elapsed >= 0.5 * expect                     # paced, with slack
    client.close()
    srv.join(10)
