"""Quantized Pallas edge path: the differential campaign.

Three contracts pinned here (see docs/quantized-edge.md):

  1. **bit-identity** — with ``weight_bits=None`` the kernel dispatch
     changes only *how* the GEMMs run, not what they compute: the
     Pallas kernel (interpret mode, whole-array blocks) and the
     pure-XLA ``ref`` twin agree bit-for-bit at EVERY candidate split
     boundary;
  2. **bounded error** — int8/int4 per-channel weight quantization errs
     by at most ``gemm_error_bound`` per layer (the affine codec's
     ``scale/2`` contract times the input's L1 norm), and the
     end-to-end logits stay close to fp32;
  3. **one contract, three backends** — a plan carrying a ``quant``
     section serves bit-identical logits through local / socket /
     streaming ``serving.connect``, survives save/load, and folds the
     section into the digest only when set.

Plus the kernel-cost calibration hook (``calibrate_quant_edge`` ->
``sweep_splits(measured_device_s=...)``), the MCU/Pi roofline check,
and a golden-numerics regression file so the quantized forward's
numerics cannot drift silently between commits.

Hypothesis property tests ride along when hypothesis is installed; the
deterministic campaign below never skips.
"""
from __future__ import annotations

import json
import os
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import serving
from repro.core.collab.protocol import affine_quantize
from repro.core.collab.quant import (BITS_LEVELS, QuantPolicy,
                                     calibrate_quant_edge,
                                     conv_weight_gemm_layout,
                                     dequantize_weights, gemm_error_bound,
                                     quant_cnn_apply, quantize_params,
                                     quantize_weights, resolve_backend)
from repro.core.partition.latency_model import (KernelCalibration,
                                                cnn_input_bytes,
                                                quantized_cnn_layer_costs)
from repro.core.partition.profiles import MCU_EDGE, PAPER_PROFILE, PI_EDGE
from repro.core.partition.splitter import sweep_splits
from repro.core.pruning.masks import cnn_masks_from_ratios
from repro.models.cnn import (cnn_apply, init_cnn_params, prunable_layers,
                              tiny_cnn_config)
from repro.roofline.analysis import (check_quant_edge_roofline,
                                     quant_edge_roofline)

pytestmark = pytest.mark.quant

GOLDEN = os.path.join(os.path.dirname(os.path.abspath(__file__)), "golden",
                      "quant_edge_golden.json")

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                   # pragma: no cover
    HAVE_HYPOTHESIS = False

needs_hypothesis = pytest.mark.skipif(
    not HAVE_HYPOTHESIS,
    reason="hypothesis not installed (pip install -r requirements-dev.txt)")


@pytest.fixture(scope="module")
def qsetup():
    cfg = tiny_cnn_config(num_classes=7, hw=32)
    params = init_cnn_params(jax.random.PRNGKey(0), cfg)
    masks = cnn_masks_from_ratios(
        params, cfg, {i: 0.5 for i in prunable_layers(cfg)})
    x = np.asarray(jax.random.normal(jax.random.PRNGKey(1), (2, 32, 32, 3)),
                   np.float32)
    return cfg, params, masks, x


# ---------------------------------------------------------------------------
# QuantPolicy: validation, serialization, digest fold
# ---------------------------------------------------------------------------
def test_quant_policy_validation_and_roundtrip():
    for bad in (dict(weight_bits=3), dict(backend="cuda"),
                dict(calibration="kl")):
        with pytest.raises(ValueError):
            QuantPolicy(**bad)
    for pol in (QuantPolicy(), QuantPolicy(weight_bits=4, per_channel=False),
                QuantPolicy(weight_bits=None, backend="ref")):
        assert QuantPolicy.from_json(pol.to_json()) == pol
    assert QuantPolicy().describe() == "int8/pc@auto"
    assert QuantPolicy(weight_bits=None, backend="ref").describe() == \
        "fp32@ref"


def test_resolve_backend_explicit():
    assert resolve_backend(QuantPolicy(backend="ref")) == ("ref", False)
    kind, interp = resolve_backend(QuantPolicy(backend="pallas"))
    assert kind == "pallas"
    if jax.default_backend() == "cpu":
        assert interp                 # no Mosaic CPU lowering: interpret


def test_plan_digest_folds_quant_only_when_set(qsetup):
    cfg, params, masks, _ = qsetup
    base = serving.DeploymentPlan.from_args(params, cfg, 6, masks=masks,
                                            compact=True)
    assert "quant" not in base.contract()            # fold-only-when-set
    q8 = serving.DeploymentPlan.from_args(
        params, cfg, 6, masks=masks, compact=True, quant=QuantPolicy())
    q4 = serving.DeploymentPlan.from_args(
        params, cfg, 6, masks=masks, compact=True,
        quant=QuantPolicy(weight_bits=4))
    assert base.digest != q8.digest != q4.digest
    # the backend is an execution detail, not part of the numerics
    # contract dimensioned keys pin — but it IS serialized, so two peers
    # still agree on it; only weight_bits/per_channel change numerics.
    assert q8.contract()["quant"]["weight_bits"] == 8


# ---------------------------------------------------------------------------
# weight quantization: the codec's bound, per channel
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("bits", [8, 4])
@pytest.mark.parametrize("per_channel", [True, False])
def test_weight_quant_error_within_half_scale(bits, per_channel):
    w = np.asarray(jax.random.normal(jax.random.PRNGKey(3), (5, 5, 6, 12)),
                   np.float32) * np.linspace(0.1, 3.0, 12)   # ragged ranges
    codes, scale, zero = quantize_weights(w, bits, per_channel)
    assert codes.dtype == np.uint8
    assert codes.max() <= BITS_LEVELS[bits]
    deq = codes.astype(np.float32) * scale + zero
    err = np.abs(deq - w)
    bound = np.broadcast_to(np.asarray(scale) * 0.5 + 1e-7, err.shape)
    assert (err <= bound).all()
    if per_channel:
        assert scale.shape == (12,)        # one (scale, zero) per channel


def test_per_channel_beats_per_tensor_on_ragged_ranges():
    w = np.asarray(jax.random.normal(jax.random.PRNGKey(4), (64, 16)),
                   np.float32) * np.r_[np.full(8, 0.05), np.full(8, 5.0)]
    for bits in (8, 4):
        pc = dequantize_weights({"wq": jnp.asarray(quantize_weights(
            w, bits, True)[0]), "scale": jnp.asarray(quantize_weights(
                w, bits, True)[1]), "zero": jnp.asarray(quantize_weights(
                    w, bits, True)[2])})
        q, s, z = quantize_weights(w, bits, False)
        pt = q.astype(np.float32) * s + z
        # the small-range channels are where per-channel wins
        small = np.abs(np.asarray(pc)[:, :8] - w[:, :8]).max()
        assert small < np.abs(pt[:, :8] - w[:, :8]).max()


def test_conv_weight_gemm_layout_matches_patch_order():
    """The GEMM-layout conv weights reproduce the conv exactly through
    im2col: (patches @ w2) == conv_general_dilated to float tolerance."""
    kh = kw = 3
    w = np.asarray(jax.random.normal(jax.random.PRNGKey(5), (kh, kw, 4, 9)),
                   np.float32)
    x = jax.random.normal(jax.random.PRNGKey(6), (2, 8, 8, 4))
    patches = jax.lax.conv_general_dilated_patches(
        x, (kh, kw), (1, 1), [(1, 1)] * 2,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    got = patches @ jnp.asarray(conv_weight_gemm_layout(w))
    want = jax.lax.conv_general_dilated(
        x, w, (1, 1), [(1, 1)] * 2,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# the differential campaign: every candidate split
# ---------------------------------------------------------------------------
def test_pallas_bit_identical_to_ref_at_every_split(qsetup):
    """weight_bits=None + whole-array interpret blocks: the Pallas
    kernel's edge prefix is BIT-identical to the pure-XLA ref at every
    candidate split boundary 0..N."""
    cfg, params, masks, x = qsetup
    qp = quantize_params(params, cfg, QuantPolicy(weight_bits=None))
    for split in range(len(cfg.layers) + 1):
        ref = quant_cnn_apply(qp, cfg, x, masks=masks, stop_layer=split,
                              backend="ref")
        pal = quant_cnn_apply(qp, cfg, x, masks=masks, stop_layer=split,
                              backend="pallas", interpret=True)
        assert np.array_equal(np.asarray(ref), np.asarray(pal)), \
            f"pallas/ref diverge at split {split}"


@pytest.mark.parametrize("bits", [8, 4])
def test_int8_layer_error_bounded_at_every_gemm(qsetup, bits):
    """Per conv/dense layer, |quantized - fp32| <= gemm_error_bound of
    that layer's true input (the provable affine contract)."""
    cfg, params, masks, x = qsetup
    fp = quantize_params(params, cfg, QuantPolicy(weight_bits=None))
    qp = quantize_params(params, cfg, QuantPolicy(weight_bits=bits))
    cur = jnp.asarray(x)
    for i, spec in enumerate(cfg.layers):
        nxt = quant_cnn_apply(fp, cfg, cur, masks=masks, start_layer=i,
                              stop_layer=i + 1)
        if spec.kind in ("conv", "dense"):
            got = quant_cnn_apply(qp, cfg, cur, masks=masks, start_layer=i,
                                  stop_layer=i + 1)
            if spec.kind == "conv":
                gin = jax.lax.conv_general_dilated_patches(
                    cur, (spec.kernel, spec.kernel),
                    (spec.stride, spec.stride),
                    [(spec.padding, spec.padding)] * 2,
                    dimension_numbers=("NHWC", "HWIO", "NHWC"))
            else:
                gin = cur
            bound = gemm_error_bound(gin, qp[f"l{i}"]["scale"])
            err = jnp.abs(got - nxt)
            slack = 1e-5 + 1e-6 * jnp.abs(nxt)   # fp32 accumulation eps
            assert bool(jnp.all(err <= bound + slack)), \
                f"layer {i} ({spec.kind}): bound violated"
        cur = nxt


def test_int8_logits_close_to_fp32_end_to_end(qsetup):
    cfg, params, masks, x = qsetup
    dense = np.asarray(cnn_apply(params, cfg, x, masks=masks))
    qp = quantize_params(params, cfg, QuantPolicy(weight_bits=8))
    q = np.asarray(quant_cnn_apply(qp, cfg, x, masks=masks))
    assert np.abs(q - dense).max() < 0.5      # tiny net, random init
    # and the kernel path itself (fp32 weights) matches dense closely
    fp = quantize_params(params, cfg, QuantPolicy(weight_bits=None))
    k = np.asarray(quant_cnn_apply(fp, cfg, x, masks=masks))
    np.testing.assert_allclose(k, dense, rtol=1e-4, atol=1e-4)


def test_golden_numerics_regression(qsetup):
    """The quantized forward's logits on a pinned seed/input, against
    the tracked golden file — catches silent numerics drift (layout,
    codec, epilogue-order changes) between commits."""
    cfg, params, masks, x = qsetup
    qp = quantize_params(params, cfg, QuantPolicy(weight_bits=8))
    got = np.asarray(quant_cnn_apply(qp, cfg, x, masks=masks),
                     np.float32)
    with open(GOLDEN) as f:
        doc = json.load(f)
    want = np.asarray(doc["int8_ref_logits"], np.float32)
    assert got.shape == tuple(doc["shape"])
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# one contract, three backends
# ---------------------------------------------------------------------------
def test_quant_plan_serves_identically_on_all_backends(qsetup):
    cfg, params, masks, x2 = qsetup
    x = x2[:1]                                 # streaming is batch-1
    plan = serving.DeploymentPlan.from_args(
        params, cfg, 6, masks=masks, compact=True, port=29621,
        shape_link=False, quant=QuantPolicy(weight_bits=8, backend="ref"))
    local = serving.connect(plan, backend="local").infer(x)
    stream = serving.connect(plan, backend="streaming",
                             realtime_channel=False).infer(x)
    np.testing.assert_array_equal(stream["logits"], local["logits"])
    with serving.CloudServer(plan):
        with serving.connect(plan, backend="socket") as sess:
            sock = sess.infer(x)
    np.testing.assert_array_equal(sock["logits"], local["logits"])
    # the quantized edge stays close to the dense logits
    dense = np.asarray(cnn_apply(params, cfg, x, masks=masks))
    assert np.abs(local["logits"] - dense).max() < 0.5


def test_quant_plan_save_load_roundtrip(qsetup, tmp_path):
    cfg, params, masks, x = qsetup
    plan = serving.DeploymentPlan.from_args(
        params, cfg, 6, masks=masks, compact=True,
        quant=QuantPolicy(weight_bits=8, backend="ref"))
    before = serving.connect(plan, backend="local").infer(x)
    loaded = serving.DeploymentPlan.load(plan.save(str(tmp_path / "q")))
    assert loaded.quant == plan.quant
    assert loaded.digest == plan.digest
    assert "quant" in loaded.describe()
    after = serving.connect(loaded, backend="local").infer(x)
    np.testing.assert_array_equal(after["logits"], before["logits"])


def test_unquantized_kernel_plan_matches_dense_plan_logits(qsetup):
    """weight_bits=None kernel dispatch through a real session: the ref
    and pallas backends agree bit-for-bit with each other (the dispatch
    contract), and with the dense plan to float tolerance (im2col
    reassociates the conv reduction, so exact equality is not owed)."""
    cfg, params, masks, x = qsetup
    kw = dict(masks=masks, compact=True)
    dense = serving.connect(serving.DeploymentPlan.from_args(
        params, cfg, 6, **kw), backend="local").infer(x)
    ref = serving.connect(serving.DeploymentPlan.from_args(
        params, cfg, 6, quant=QuantPolicy(weight_bits=None, backend="ref"),
        **kw), backend="local").infer(x)
    pal = serving.connect(serving.DeploymentPlan.from_args(
        params, cfg, 6,
        quant=QuantPolicy(weight_bits=None, backend="pallas"), **kw),
        backend="local").infer(x)
    np.testing.assert_array_equal(ref["logits"], pal["logits"])
    np.testing.assert_allclose(ref["logits"], dense["logits"],
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# calibration hook + roofline check
# ---------------------------------------------------------------------------
def test_calibration_feeds_split_sweep(qsetup):
    cfg, params, masks, x = qsetup
    qp = quantize_params(params, cfg, QuantPolicy(weight_bits=8))
    cal = calibrate_quant_edge(qp, cfg, x[:1], masks=masks, repeats=2)
    assert isinstance(cal, KernelCalibration)
    assert len(cal.layer_s) == len(cfg.layers)
    assert all(t > 0 for t in cal.layer_s)
    assert cal.total_s(4) <= cal.total_s() + 1e-12
    rows = sweep_splits(quantized_cnn_layer_costs(cfg, masks, 8),
                        PAPER_PROFILE, cnn_input_bytes(cfg),
                        measured_device_s=cal.layer_s)
    assert len(rows) == len(cfg.layers) + 1
    best = min(rows, key=lambda r: r["T"])
    assert 0 <= best["split"] <= len(cfg.layers)


@pytest.mark.parametrize("profile", [MCU_EDGE, PI_EDGE],
                         ids=lambda p: p.name)
def test_quantized_fc_layers_reach_memory_bound_ceiling(qsetup, profile):
    """The headline roofline claim: int8 weight streaming puts the
    batch-1 fc GEMMs in the memory-bound regime on both edge profiles."""
    cfg, _, masks, _ = qsetup
    rows = check_quant_edge_roofline(cfg, masks, profile, weight_bits=8)
    fc = [r for r in rows if r["name"].startswith("fc")]
    assert fc and all(r["memory_bound"] for r in fc)
    assert all(r["memory_share"] >= 0.5 for r in fc)


def test_fp32_fc_layers_stay_compute_bound_on_mcu(qsetup):
    """The contrast that makes the int8 story meaningful: at fp32 the
    MCU's soft-float throughput keeps the same fc layers compute-bound."""
    cfg, _, masks, _ = qsetup
    rows = quant_edge_roofline(cfg, masks, MCU_EDGE, weight_bits=None)
    fc = [r for r in rows if r["name"].startswith("fc")]
    assert fc and not any(r["memory_bound"] for r in fc)
    with pytest.raises(AssertionError, match="compute-bound"):
        check_quant_edge_roofline(cfg, masks, MCU_EDGE, weight_bits=None)


# ---------------------------------------------------------------------------
# hypothesis property tests (skip cleanly when not installed)
# ---------------------------------------------------------------------------
if HAVE_HYPOTHESIS:
    SET = settings(max_examples=25, deadline=None)

    @needs_hypothesis
    @SET
    @given(st.integers(1, 24), st.integers(1, 48), st.integers(1, 32),
           st.integers(0, 2 ** 31 - 1))
    def test_prop_pallas_whole_block_bit_identical(m, k, n, seed):
        from repro.core.collab.quant import _gemm
        ka, kb, km = jax.random.split(jax.random.PRNGKey(seed), 3)
        a = jax.random.normal(ka, (m, k), jnp.float32)
        b = jax.random.normal(kb, (k, n), jnp.float32)
        mask = (jax.random.uniform(km, (n,)) > 0.5).astype(jnp.float32)
        got = _gemm(a, b, mask, "pallas", True)
        want = _gemm(a, b, mask, "ref", False)
        assert np.array_equal(np.asarray(got), np.asarray(want))

    @needs_hypothesis
    @SET
    @given(st.integers(1, 40), st.integers(1, 24),
           st.sampled_from([8, 4]), st.integers(0, 2 ** 31 - 1))
    def test_prop_gemm_error_bound_holds(k, n, bits, seed):
        kw, kx = jax.random.split(jax.random.PRNGKey(seed))
        w = np.asarray(jax.random.normal(kw, (k, n)), np.float32) * 3.0
        x = jax.random.normal(kx, (2, k), jnp.float32)
        codes, scale, zero = quantize_weights(w, bits, True)
        deq = codes.astype(np.float32) * scale + zero
        err = jnp.abs(x @ jnp.asarray(deq) - x @ jnp.asarray(w))
        bound = gemm_error_bound(x, scale)
        assert bool(jnp.all(err <= bound + 1e-4))

    @needs_hypothesis
    @SET
    @given(st.integers(2, 200), st.sampled_from([255, 15]),
           st.integers(0, 2 ** 31 - 1))
    def test_prop_affine_quantize_half_scale(size, levels, seed):
        rng = np.random.RandomState(seed % (2 ** 32 - 1))
        x = (rng.randn(size) * rng.uniform(0.01, 10)).astype(np.float32)
        q, scale, zero = affine_quantize(x, levels)
        assert q.dtype == np.uint8 and q.max() <= levels
        deq = q.astype(np.float32) * scale + zero
        assert np.abs(deq - x).max() <= scale * 0.5 + 1e-6
