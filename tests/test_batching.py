"""Cross-client dynamic batching engine: bit-identical batched logits
across codecs, lane isolation, window flush, ragged-batch padding,
bucketed-compilation warm (no steady-state tracing), the shared
server-side link shaper, and the plan's ``batching`` contract section."""
from __future__ import annotations

import socket
import threading
import time

import jax
import numpy as np
import pytest

from repro import serving
from repro.core.collab.batching import (BatchingPolicy, DynamicBatcher,
                                        bucket_for, default_buckets)
from repro.core.collab.channel import LinkShaper, ShapedSocket
from repro.core.collab.protocol import (decode_any, encode_feature,
                                        frame_lane)
from repro.core.collab.runtime import SplitFnBank
from repro.core.partition.profiles import LinkProfile
from repro.core.pruning.masks import cnn_masks_from_ratios
from repro.models.cnn import (init_cnn_params, prunable_layers,
                              tiny_cnn_config)

SPLIT = 3


@pytest.fixture(scope="module")
def setup():
    cfg = tiny_cnn_config(num_classes=7, hw=32)
    params = init_cnn_params(jax.random.PRNGKey(0), cfg)
    masks = cnn_masks_from_ratios(
        params, cfg, {i: 0.5 for i in prunable_layers(cfg)})
    rng = np.random.RandomState(0)
    imgs = [rng.rand(1, 32, 32, 3).astype(np.float32) for _ in range(11)]
    return cfg, params, masks, imgs


@pytest.fixture(scope="module")
def bank(setup):
    cfg, params, masks, _ = setup
    return SplitFnBank(params, cfg, masks, compact=True)


# ---------------------------------------------------------------------------
# policy + buckets
# ---------------------------------------------------------------------------
def test_default_buckets_and_bucket_for():
    assert default_buckets(8) == (1, 2, 4, 8)
    assert default_buckets(6) == (1, 2, 4, 6)
    assert default_buckets(1) == (1,)
    assert bucket_for(3, (1, 2, 4, 8)) == 4
    assert bucket_for(8, (1, 2, 4, 8)) == 8
    with pytest.raises(ValueError):
        bucket_for(9, (1, 2, 4, 8))


def test_policy_validation_and_json_roundtrip():
    p = BatchingPolicy(max_batch=8, max_wait_ms=2.5, buckets=(1, 4, 8))
    assert BatchingPolicy.from_json(p.to_json()) == p
    assert p.resolved_buckets == (1, 4, 8)
    assert BatchingPolicy(max_batch=6).resolved_buckets == (1, 2, 4, 6)
    with pytest.raises(ValueError):
        BatchingPolicy(max_batch=0)
    with pytest.raises(ValueError):
        BatchingPolicy(max_batch=8, max_wait_ms=-1)
    with pytest.raises(ValueError):
        BatchingPolicy(max_batch=8, buckets=(1, 4))      # must end at max
    with pytest.raises(ValueError):
        BatchingPolicy(max_batch=8, buckets=(4, 1, 8))   # must be sorted


# ---------------------------------------------------------------------------
# the engine: bit-identity, lanes, flush, padding
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("codec,pack", [("fp32", False), ("fp16", False),
                                        ("int8", True)])
def test_batched_logits_bit_identical_across_codecs(setup, codec, pack):
    """Batched-vs-sequential must agree BITWISE per codec: frames are
    encoded/decoded exactly as the sequential path does it, then fused
    through the engine's row-mapped cloud call."""
    cfg, params, masks, imgs = setup
    bank = SplitFnBank(params, cfg, masks, compact=False, pack=pack)
    edge_fn, cloud_fn, keep = bank.get(SPLIT)
    frames = [encode_feature(np.asarray(edge_fn(im)), codec=codec,
                             keep=keep) for im in imgs]
    decoded = [decode_any(f)[0] for f in frames]
    sequential = [np.asarray(cloud_fn(d)) for d in decoded]

    eng = DynamicBatcher(bank, BatchingPolicy(max_batch=8, max_wait_ms=20.0))
    futs = [eng.submit(SPLIT, frame_lane(frames[i]), decoded[i])
            for i in range(len(imgs))]
    outs = [f.result(timeout=30) for f in futs]
    eng.stop()
    for seq, got in zip(sequential, outs):
        assert np.array_equal(seq, got)
    stats = next(iter(eng.stats().values()))
    assert stats["batches"] < len(imgs)          # genuinely fused


def test_mixed_split_lanes_are_isolated(setup, bank):
    """Tensors for different splits have different shapes — the engine
    must key them into separate lanes and answer each with the right
    cloud sub-model."""
    cfg, params, masks, imgs = setup
    splits = (2, 5)
    feats = {c: [np.asarray(bank.get(c)[0](im)) for im in imgs[:4]]
             for c in splits}
    want = {c: [np.asarray(bank.get(c)[1](f)) for f in feats[c]]
            for c in splits}
    eng = DynamicBatcher(bank, BatchingPolicy(max_batch=4, max_wait_ms=10.0))
    futs = [(c, i, eng.submit(c, "fp32", feats[c][i]))
            for i in range(4) for c in splits]          # interleaved
    for c, i, f in futs:
        assert np.array_equal(want[c][i], f.result(timeout=30))
    eng.stop()
    stats = eng.stats()
    assert len(stats) == 2                       # one lane per split
    for lane in stats.values():
        assert lane["rows"] == 4


def test_partial_batch_flushes_on_window(setup, bank):
    """3 requests < max_batch must not wait forever: the window expires
    and the partial batch runs (padded to the next bucket)."""
    eng = DynamicBatcher(bank, BatchingPolicy(max_batch=8, max_wait_ms=30.0))
    imgs = setup[3]
    feats = [np.asarray(bank.get(SPLIT)[0](im)) for im in imgs[:3]]
    t0 = time.perf_counter()
    futs = [eng.submit(SPLIT, "fp32", f) for f in feats]
    outs = [f.result(timeout=10) for f in futs]
    elapsed = time.perf_counter() - t0
    eng.stop()
    assert elapsed < 5.0                         # flushed, not starved
    want = [np.asarray(bank.get(SPLIT)[1](f)) for f in feats]
    for a, b in zip(want, outs):
        assert np.array_equal(a, b)
    lane = next(iter(eng.stats().values()))
    assert lane["batch_sizes"] == [3]
    assert lane["padded_rows"] == 1              # 3 padded to bucket 4
    assert lane["padding_waste"] == pytest.approx(0.25)


def test_ragged_final_batch_padding_masked_out(setup, bank):
    """Padded rows (zeros) must never leak into returned logits, and a
    multi-row frame comes back with exactly its own rows."""
    cfg, params, masks, imgs = setup
    edge_fn, cloud_fn, _ = bank.get(SPLIT)
    feats5 = np.concatenate([np.asarray(edge_fn(im)) for im in imgs[:5]],
                            axis=0)
    want = np.concatenate([np.asarray(cloud_fn(np.asarray(edge_fn(im))))
                           for im in imgs[:5]], axis=0)
    eng = DynamicBatcher(bank, BatchingPolicy(max_batch=8, max_wait_ms=5.0))
    out = eng.submit(SPLIT, "fp32", feats5).result(timeout=30)
    eng.stop()
    assert out.shape[0] == 5                     # bucket-8 padding removed
    assert np.array_equal(want, out)
    lane = next(iter(eng.stats().values()))
    assert lane["padded_rows"] == 3


def test_submit_rejects_oversized_frame(setup, bank):
    eng = DynamicBatcher(bank, BatchingPolicy(max_batch=2))
    with pytest.raises(ValueError):
        eng.submit(SPLIT, "fp32", np.zeros((3, 16, 16, 48), np.float32))
    eng.stop()


# ---------------------------------------------------------------------------
# bucketed compilation: warm covers splits x buckets, steady state quiet
# ---------------------------------------------------------------------------
def test_warm_buckets_then_no_new_tracing(setup):
    """Satellite regression: ``warm`` used to pre-jit batch-1 only. After
    warming the configured buckets, batched calls at any fused size must
    perform no new tracing."""
    cfg, params, masks, imgs = setup
    bank = SplitFnBank(params, cfg, masks, compact=True)
    policy = BatchingPolicy(max_batch=8, max_wait_ms=5.0)
    splits = (2, SPLIT)
    bank.warm(splits, np.zeros((1, 32, 32, 3), np.float32),
              buckets=policy.resolved_buckets)
    baseline = bank.n_traces
    assert baseline > 0
    eng = DynamicBatcher(bank, policy)
    for c in splits:
        feats = [np.asarray(bank.get(c)[0](im)) for im in imgs]
        futs = [eng.submit(c, "fp32", f) for f in feats]   # 11 -> 8 + 3(4)
        for f in futs:
            f.result(timeout=30)
    eng.stop()
    assert bank.n_traces == baseline, (
        f"batched serving traced {bank.n_traces - baseline} new "
        f"function(s) after warm")


def test_unwarmed_bucket_does_trace(setup):
    """Sanity for the counter itself: a bucket shape warm never saw DOES
    trace (so the regression test above is meaningful)."""
    cfg, params, masks, imgs = setup
    bank = SplitFnBank(params, cfg, masks, compact=True)
    bank.warm([SPLIT], np.zeros((1, 32, 32, 3), np.float32), buckets=(1, 2))
    baseline = bank.n_traces
    feats = np.repeat(np.asarray(bank.get(SPLIT)[0](imgs[0])), 4, axis=0)
    jax.block_until_ready(bank.get(SPLIT, batch_bucket=4)[1](feats))
    assert bank.n_traces > baseline


# ---------------------------------------------------------------------------
# shared link shaper (one token bucket per physical medium)
# ---------------------------------------------------------------------------
def _timed_send(sock, nbytes, out, i):
    payload = b"x" * nbytes
    t0 = time.perf_counter()
    sock.sendall(payload)
    out[i] = time.perf_counter() - t0


def test_two_senders_on_shared_shaper_halve_goodput():
    """Satellite regression: two concurrent edges used to each get a
    private token bucket — 2x the physical link. On a shared shaper they
    contend: per-edge goodput halves (wall doubles)."""
    link = LinkProfile("test 4 MB/s", bandwidth=4e6, rtt_s=0.0)
    nbytes = 200_000                              # 50 ms alone at 4 MB/s

    def drain(s):
        try:
            while s.recv(1 << 16):
                pass
        except OSError:
            pass

    def run(n_senders, shared):
        pairs = [socket.socketpair() for _ in range(n_senders)]
        shaper = LinkShaper(link) if shared else None
        socks = [ShapedSocket(a, link, shaper=shaper) for a, _ in pairs]
        for _, b in pairs:
            threading.Thread(target=drain, args=(b,), daemon=True).start()
        out = [0.0] * n_senders
        ts = [threading.Thread(target=_timed_send,
                               args=(s, nbytes, out, i))
              for i, s in enumerate(socks)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        for a, b in pairs:
            a.close()
            b.close()
        return max(out)

    alone = run(1, shared=True)
    together = run(2, shared=True)
    private = run(2, shared=False)
    # shared medium: 2 senders take ~2x the single-sender wall;
    # private buckets (the old bug) let both finish in ~1x
    assert together >= 1.6 * alone, (alone, together)
    assert private <= 1.4 * alone, (alone, private)


def test_serve_cloud_connections_share_one_shaper(setup, monkeypatch):
    """Structural check on the server: every connection handler's
    ShapedSocket must draw from the same LinkShaper instance."""
    import repro.core.collab.runtime as rt
    cfg, params, masks, imgs = setup
    seen = []
    real = rt.ShapedSocket

    class Recording(real):
        def __init__(self, sock, link, chunk=16384, trace=None,
                     shaper=None):
            seen.append(shaper)
            super().__init__(sock, link, chunk=chunk, trace=trace,
                             shaper=shaper)

    monkeypatch.setattr(rt, "ShapedSocket", Recording)
    plan = serving.DeploymentPlan.from_args(
        params, cfg, SPLIT, masks=masks, compact=True, shape_link=True,
        port=29860)
    with serving.CloudServer(plan, max_clients=None) as srv:
        sessions = [serving.connect(plan, backend="socket")
                    for _ in range(2)]
        for s in sessions:
            s.infer(imgs[0])
        for s in sessions:
            s.close()
        srv.stop()
    server_side = [sh for sh in seen if sh is not None]
    assert len(server_side) >= 2
    assert len({id(sh) for sh in server_side}) == 1


# ---------------------------------------------------------------------------
# plan contract: the batching section
# ---------------------------------------------------------------------------
def test_plan_batching_digest_semantics(setup):
    cfg, params, masks, _ = setup

    def mk(**kw):
        return serving.DeploymentPlan.from_args(
            params, cfg, SPLIT, masks=masks, compact=True, **kw)

    plain = mk()
    batched = mk(batching=BatchingPolicy(max_batch=8))
    assert plain.digest != batched.digest        # folded when set
    assert mk().digest == plain.digest           # pre-batching stable
    assert batched.digest == mk(
        batching=BatchingPolicy(max_batch=8)).digest
    assert batched.digest != mk(
        batching=BatchingPolicy(max_batch=4)).digest
    assert "batched" in batched.describe()


def test_plan_batching_save_load_roundtrip(setup, tmp_path):
    cfg, params, masks, _ = setup
    plan = serving.DeploymentPlan.from_args(
        params, cfg, SPLIT, masks=masks, compact=True,
        batching=BatchingPolicy(max_batch=4, max_wait_ms=7.0))
    path = plan.save(str(tmp_path / "plan"))
    got = serving.DeploymentPlan.load(path)
    assert got.digest == plan.digest
    assert got.batching == plan.batching


# ---------------------------------------------------------------------------
# end to end: batched socket serving + local fast path
# ---------------------------------------------------------------------------
def test_batched_socket_serving_bit_identical_and_batching(setup):
    """2 pipelined edges against one batched cloud: logits bit-identical
    to sequential local serving, and the server's lane stats prove
    cross-client fusion actually happened."""
    cfg, params, masks, imgs = setup
    policy = BatchingPolicy(max_batch=8, max_wait_ms=10.0)
    plan = serving.DeploymentPlan.from_args(
        params, cfg, SPLIT, masks=masks, compact=True, codec="int8",
        shape_link=False, port=29861, batching=policy)
    ref_plan = serving.DeploymentPlan.from_args(
        params, cfg, SPLIT, masks=masks, compact=True, codec="int8",
        shape_link=False)
    with serving.connect(ref_plan, backend="local") as ref_sess:
        ref = [ref_sess.infer(im)["logits"] for im in imgs]

    outs = [None, None]
    with serving.CloudServer(plan, max_clients=None) as srv:
        def edge(i):
            with serving.connect(plan, backend="socket") as s:
                outs[i] = s.infer_many(imgs)
        ts = [threading.Thread(target=edge, args=(i,)) for i in range(2)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        srv.stop()
        stats = dict(srv.batch_stats)
    for per_edge in outs:
        for a, b in zip(ref, per_edge):
            assert np.array_equal(a, b["logits"])
    lane = next(iter(stats.values()))
    assert lane["rows"] == 2 * len(imgs)
    assert lane["avg_batch"] > 1.0               # cross-client fusion


def test_infer_batch_handles_multi_row_requests(setup):
    """A request may itself be a multi-row image batch: per-request
    frames and returned logits must carve the fused tensor at the row
    offsets, not one-row-per-request."""
    from repro.core.collab.runtime import CollabRunner
    from repro.core.partition.profiles import PAPER_PROFILE
    cfg, params, masks, imgs = setup
    runner = CollabRunner(params, cfg, SPLIT, PAPER_PROFILE, masks=masks,
                          compact=True, codec="fp32")
    two = np.concatenate([imgs[0], imgs[1]], axis=0)       # (2, H, W, C)
    # the engine is row-mapped: each ROW must match its batch-1 result
    # bitwise (a 2-row request through sequential infer would use a true
    # batch-2 conv, which XLA may legally re-associate)
    singles = [runner.infer(im)["logits"] for im in imgs[:3]]
    got = runner.infer_batch([two, imgs[2]])
    assert got[0]["logits"].shape[0] == 2
    assert got[1]["logits"].shape[0] == 1
    assert np.array_equal(singles[0][0], got[0]["logits"][0])
    assert np.array_equal(singles[1][0], got[0]["logits"][1])
    assert np.array_equal(singles[2], got[1]["logits"])


def test_local_fast_path_and_batched_server_accept_multi_row(setup):
    """Requests wider than one row — and even wider than max_batch —
    must serve on a batching plan exactly like they do without one
    (fast path chunks by ROWS; the server bypasses the engine for
    frames no bucket can hold)."""
    cfg, params, masks, imgs = setup
    wide = np.concatenate(imgs[:5], axis=0)          # 5 rows > max_batch 4
    two = np.concatenate(imgs[:2], axis=0)
    batch = [two, imgs[2], wide, imgs[3]]
    plan = serving.DeploymentPlan.from_args(
        params, cfg, SPLIT, masks=masks, compact=True, codec="fp32",
        shape_link=False, port=29862,
        batching=BatchingPolicy(max_batch=4, max_wait_ms=2.0))
    with serving.connect(plan, backend="local") as s:
        res = s.infer_many(batch)
    assert [r["logits"].shape[0] for r in res] == [2, 1, 5, 1]
    with serving.CloudServer(plan, max_clients=None) as srv:
        with serving.connect(plan, backend="socket") as s:
            got = [s.infer(x) for x in batch]
        srv.stop()
    assert [r["logits"].shape[0] for r in got] == [2, 1, 5, 1]


def test_local_session_infer_many_fast_path_bit_identical(setup):
    cfg, params, masks, imgs = setup
    plan = serving.DeploymentPlan.from_args(
        params, cfg, SPLIT, masks=masks, compact=True, codec="int8",
        batching=BatchingPolicy(max_batch=4, max_wait_ms=2.0))
    with serving.connect(plan, backend="local") as s:
        seq = [s.infer(im)["logits"] for im in imgs]
    with serving.connect(plan, backend="local") as s:
        fast = s.infer_many(imgs)
    assert len(fast) == len(imgs)
    for a, b in zip(seq, fast):
        assert np.array_equal(a, b["logits"])
    # tx accounting preserved per request on the fused path
    assert all(r["tx_bytes"] > 0 for r in fast)
