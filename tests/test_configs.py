"""Assigned-architecture configs: exact published shapes + smoke reductions."""
from __future__ import annotations

import pytest

from repro.configs.registry import ARCH_IDS, get_config, get_smoke_config

# (layers, d_model, heads, kv_heads, d_ff, vocab) from the assignment table
EXPECTED = {
    "mamba2-2.7b": (64, 2560, None, None, 0, 50280),
    "gemma-7b": (28, 3072, 16, 16, 24576, 256000),
    "qwen1.5-4b": (40, 2560, 20, 20, 6912, 151936),
    "qwen2-7b": (28, 3584, 28, 4, 18944, 152064),
    "hubert-xlarge": (48, 1280, 16, 16, 5120, 504),
    "nemotron-4-340b": (96, 18432, 96, 8, 73728, 256000),
    "qwen2-vl-7b": (28, 3584, 28, 4, 18944, 152064),
    "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
    # assignment's "d_ff=2048" is the per-expert dim (checked separately)
    "deepseek-v3-671b": (61, 7168, 128, 128, None, 129280),
    "mixtral-8x7b": (32, 4096, 32, 8, 14336, 32000),
}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_exact_published_shape(arch):
    cfg = get_config(arch)
    L, d, H, Hkv, dff, V = EXPECTED[arch]
    assert cfg.num_layers == L
    assert cfg.d_model == d
    if H is not None:
        assert cfg.num_heads == H
        assert cfg.num_kv_heads == Hkv
    if dff is not None:
        assert cfg.d_ff == dff
    assert cfg.vocab_size == V
    assert cfg.citation, f"{arch} must cite its source"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_reduction_limits(arch):
    cfg = get_smoke_config(arch)
    assert cfg.num_layers <= 2
    assert cfg.d_model <= 512
    if cfg.moe is not None:
        assert cfg.moe.num_experts <= 4


def test_arch_specifics():
    assert get_config("mamba2-2.7b").ssm.d_state == 128
    assert get_config("mamba2-2.7b").attention == "none"
    assert get_config("gemma-7b").head_dim == 256
    assert get_config("gemma-7b").activation == "geglu"
    assert get_config("qwen1.5-4b").qkv_bias
    assert get_config("qwen2-7b").qkv_bias
    assert get_config("hubert-xlarge").causal is False
    assert get_config("nemotron-4-340b").activation == "sq_relu"
    assert get_config("qwen2-vl-7b").rope_mode == "mrope"
    assert get_config("zamba2-1.2b").ssm.d_state == 64
    assert get_config("zamba2-1.2b").shared_attn_period > 0
    ds = get_config("deepseek-v3-671b")
    assert ds.moe.d_expert == 2048               # assignment's "d_ff=2048"
    assert ds.moe.num_experts == 256 and ds.moe.top_k == 8
    assert ds.moe.num_shared == 1 and ds.attention == "mla"
    assert ds.mtp_depth == 1
    mx = get_config("mixtral-8x7b")
    assert mx.moe.num_experts == 8 and mx.moe.top_k == 2
    assert mx.sliding_window is not None
