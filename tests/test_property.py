"""Hypothesis property tests on the system's invariants."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis",
                    reason="hypothesis not installed "
                           "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core.partition.latency_model import LayerCost, split_latency
from repro.core.partition.profiles import PAPER_PROFILE
from repro.core.partition.splitter import balanced_split, greedy_split
from repro.core.pruning.amc_env import LayerDesc, PruningEnv
from repro.core.pruning.masks import _topk_mask
from repro.kernels.masked_matmul.ops import masked_matmul
from repro.kernels.masked_matmul.ref import masked_matmul_ref
from repro.kernels.rmsnorm.ops import rmsnorm
from repro.kernels.rmsnorm.ref import rmsnorm_ref

SET = settings(max_examples=25, deadline=None)


# ---------------------------------------------------------------------------
# kernels
# ---------------------------------------------------------------------------
@SET
@given(rows=st.integers(1, 40), d=st.sampled_from([8, 32, 96]),
       seed=st.integers(0, 2**31 - 1))
def test_rmsnorm_matches_ref_any_shape(rows, d, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (rows, d))
    s = jax.random.normal(jax.random.PRNGKey(seed + 1), (d,))
    np.testing.assert_allclose(
        np.asarray(rmsnorm(x, s, interpret=True)),
        np.asarray(rmsnorm_ref(x, s)), rtol=3e-5, atol=3e-5)


@SET
@given(m=st.integers(1, 50), k=st.integers(1, 60), n=st.integers(1, 50),
       seed=st.integers(0, 2**31 - 1))
def test_masked_matmul_matches_ref_any_shape(m, k, n, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    a = jax.random.normal(ks[0], (m, k))
    b = jax.random.normal(ks[1], (k, n))
    mask = (jax.random.uniform(ks[2], (n,)) > 0.5).astype(jnp.float32)
    np.testing.assert_allclose(
        np.asarray(masked_matmul(a, b, mask, block_m=16, block_n=16,
                                 block_k=16, interpret=True)),
        np.asarray(masked_matmul_ref(a, b, mask)), rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# latency model / splitter
# ---------------------------------------------------------------------------
def _rand_costs(rng, n):
    return [LayerCost(i, f"l{i}", float(rng.uniform(1e6, 1e9)),
                      float(rng.uniform(1e3, 1e6))) for i in range(n)]


@SET
@given(n=st.integers(1, 20), seed=st.integers(0, 10_000))
def test_greedy_split_is_global_argmin(n, seed):
    rng = np.random.RandomState(seed)
    costs = _rand_costs(rng, n)
    dec = greedy_split(costs, PAPER_PROFILE, input_bytes=73_500.0)
    brute = min((split_latency(costs, c, PAPER_PROFILE, 73_500.0)["T"], c)
                for c in range(n + 1))
    assert abs(dec.latency["T"] - brute[0]) < 1e-12


@SET
@given(n=st.integers(1, 15), seed=st.integers(0, 10_000))
def test_split_latency_terms_consistent(n, seed):
    rng = np.random.RandomState(seed)
    costs = _rand_costs(rng, n)
    for c in range(n + 1):
        row = split_latency(costs, c, PAPER_PROFILE, 73_500.0)
        assert row["T"] == row["T_D"] + row["T_TX"] + row["T_S"]
        assert row["T_D"] >= 0 and row["T_TX"] >= 0 and row["T_S"] >= 0
    # edge cases: c=0 transmits the raw input; c=n transmits nothing
    assert split_latency(costs, 0, PAPER_PROFILE, 73_500.0)["tx_bytes"] == 73_500.0
    assert split_latency(costs, n, PAPER_PROFILE, 73_500.0)["T_TX"] == 0.0


@SET
@given(n=st.integers(1, 15), seed=st.integers(0, 10_000))
def test_balanced_split_minimizes_bottleneck(n, seed):
    rng = np.random.RandomState(seed)
    costs = _rand_costs(rng, n)
    dec = balanced_split(costs, PAPER_PROFILE, 73_500.0)
    bn = max(dec.latency["T_D"], dec.latency["T_TX"], dec.latency["T_S"])
    for c in range(n + 1):
        row = split_latency(costs, c, PAPER_PROFILE, 73_500.0)
        assert bn <= max(row["T_D"], row["T_TX"], row["T_S"]) + 1e-12


@SET
@given(seed=st.integers(0, 10_000))
def test_pruning_shrinks_latency_model(seed):
    """More aggressive pruning never increases any latency term (CNN model)."""
    from repro.core.partition.latency_model import cnn_layer_costs
    from repro.models.cnn import tiny_cnn_config
    cfg = tiny_cnn_config()
    rng = np.random.RandomState(seed)
    li = [i for i, s in enumerate(cfg.layers) if s.kind == "conv"]
    keep_hi, keep_lo = {}, {}
    for i in li:
        n = cfg.layers[i].out_channels
        khi = rng.randint(n // 2, n + 1)
        klo = rng.randint(1, khi + 1)
        m = np.zeros(n, np.float32)
        m[:khi] = 1
        keep_hi[i] = jnp.asarray(m)
        m2 = np.zeros(n, np.float32)
        m2[:klo] = 1
        keep_lo[i] = jnp.asarray(m2)
    hi = cnn_layer_costs(cfg, keep_hi)
    lo = cnn_layer_costs(cfg, keep_lo)
    assert sum(c.flops for c in lo) <= sum(c.flops for c in hi) + 1e-9
    assert all(a.out_bytes <= b.out_bytes + 1e-9 for a, b in zip(lo, hi))


# ---------------------------------------------------------------------------
# pruning env / masks
# ---------------------------------------------------------------------------
@SET
@given(n=st.integers(2, 30), ratio=st.floats(0.01, 1.0),
       seed=st.integers(0, 10_000))
def test_topk_mask_keep_count(n, ratio, seed):
    imp = np.random.RandomState(seed).rand(n).astype(np.float32)
    m = _topk_mask(imp, ratio)
    k = int(m.sum())
    assert k == max(1, min(n, int(round(ratio * n))))
    # kept units are the top-k by importance
    kept = np.sort(imp[m > 0])
    dropped = imp[m == 0]
    if dropped.size:
        assert kept.min() >= dropped.max() - 1e-9


@SET
@given(budget=st.floats(0.2, 0.9), seed=st.integers(0, 10_000))
def test_amc_clipping_keeps_budget_reachable(budget, seed):
    rng = np.random.RandomState(seed)
    descs = [LayerDesc(i, 64, 64, 8, 8, 1, 3, float(rng.uniform(1e6, 1e9)),
                       in_coupled=False)
             for i in range(6)]
    env = PruningEnv(descs, evaluate=lambda r: 0.5, flops_budget=budget,
                     action_floor=0.1)
    rec = env.run_episode(lambda s, i: 1.0)     # agent always asks "keep all"
    assert rec["flops_kept"] <= budget + 0.15   # floor granularity slack
    # every action respects the floor and ceiling
    assert all(env.floor <= a <= 1.0 for a in rec["actions"])


@SET
@given(seed=st.integers(0, 10_000))
def test_env_state_normalized(seed):
    rng = np.random.RandomState(seed)
    descs = [LayerDesc(i, 64, 64, 8, 8, 1, 3, float(rng.uniform(1e6, 1e9)))
             for i in range(5)]
    env = PruningEnv(descs, evaluate=lambda r: 0.5)
    for i in range(len(descs)):
        s = env.state(i, 0.0, env.total_flops, 1.0)
        assert s.shape == (11,)
        assert np.all(s <= 1.0 + 1e-6) and np.all(s >= -1e-6)
